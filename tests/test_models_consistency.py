"""Serving-path correctness: prefill + decode must reproduce the full
forward pass (validates KV caches incl. MLA latent cache, ring buffers,
SSM/WKV states, token-shift states)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import forward, init_caches, init_model

KEY = jax.random.PRNGKey(1)
B, S, CAP = 2, 33, 48

DECODERS = [a for a in ARCHS if not get_config(a).is_encoder]


@pytest.mark.parametrize("arch", DECODERS)
def test_prefill_then_decode_matches_full(arch):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = init_model(cfg, KEY)
    st = S - (cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0)
    toks = jax.random.randint(KEY, (B, st + 1), 0, cfg.vocab_size)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :-1]}
    if cfg.frontend == "vision_stub":
        patches = jax.random.normal(KEY, (B, cfg.frontend_tokens,
                                          cfg.frontend_dim))
        full["patches"] = patches
        pre["patches"] = patches
    lg_full, _, _ = forward(params, cfg, full)
    caches = init_caches(cfg, B, CAP, dtype=jnp.float32)
    _, caches, _ = forward(params, cfg, pre, mode="prefill", caches=caches)
    lg_dec, _, _ = forward(params, cfg, {"tokens": toks[:, -1:]},
                           mode="decode", caches=caches, pos=jnp.asarray(S))
    V = cfg.vocab_size
    err = float(jnp.abs(lg_full[:, -1, :V] - lg_dec[:, 0, :V]).max())
    scale = max(float(jnp.abs(lg_full[:, -1, :V]).max()), 1.0)
    assert err < 2e-3 * scale, f"{arch}: err={err:.3e} scale={scale:.1f}"


@pytest.mark.parametrize("arch", ["gemma2-2b", "mixtral-8x22b"])
def test_ring_buffer_window_decode(arch):
    """Sliding-window cache: decode far beyond the window capacity must
    match a full forward that only sees the window anyway."""
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = init_model(cfg, KEY)
    n = cfg.window + 9        # go past capacity to exercise the ring
    toks = jax.random.randint(KEY, (B, n + 1), 0, cfg.vocab_size)
    lg_full, _, _ = forward(params, cfg, {"tokens": toks})
    caches = init_caches(cfg, B, 2 * cfg.window)
    _, caches, _ = forward(params, cfg, {"tokens": toks[:, :-1]},
                           mode="prefill", caches=caches)
    lg_dec, _, _ = forward(params, cfg, {"tokens": toks[:, -1:]},
                           mode="decode", caches=caches,
                           pos=jnp.asarray(n))
    V = cfg.vocab_size
    err = float(jnp.abs(lg_full[:, -1, :V] - lg_dec[:, 0, :V]).max())
    scale = max(float(jnp.abs(lg_full[:, -1, :V]).max()), 1.0)
    assert err < 5e-3 * scale, f"{arch}: err={err:.3e}"


def test_multi_step_decode_matches_full():
    """Three consecutive decode steps track the full forward."""
    cfg = get_config("smollm-360m", reduced=True).replace(dtype="float32")
    params = init_model(cfg, KEY)
    n = 20
    toks = jax.random.randint(KEY, (B, n + 3), 0, cfg.vocab_size)
    lg_full, _, _ = forward(params, cfg, {"tokens": toks})
    caches = init_caches(cfg, B, 64, dtype=jnp.float32)
    _, caches, _ = forward(params, cfg, {"tokens": toks[:, :n]},
                           mode="prefill", caches=caches)
    for i in range(3):
        lg, caches, _ = forward(params, cfg,
                                {"tokens": toks[:, n + i: n + i + 1]},
                                mode="decode", caches=caches,
                                pos=jnp.asarray(n + i))
        err = float(jnp.abs(lg_full[:, n + i] - lg[:, 0]).max())
        assert err < 1e-3 * max(float(jnp.abs(lg_full[:, n + i]).max()), 1.0)
