"""Training substrate tests: optimizer, train loop convergence,
checkpoint/restore (incl. elastic resharding), fault tolerance,
stragglers, gradient compression, data pipeline determinism."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import init_model
from repro.runtime import FaultTolerantLoop, StragglerMonitor
from repro.train import adamw, cosine_schedule
from repro.train.grad_compress import (compress_residual, dequantize_int8,
                                       quantize_int8)
from repro.train.optimizer import clip_by_global_norm, global_norm
from repro.train.train_step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def tiny_setup(accum=1):
    cfg = get_config("smollm-360m", reduced=True)
    params = init_model(cfg, KEY)
    opt = adamw(lr=5e-3, weight_decay=0.0)
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt, accum_steps=accum))
    pipe = Pipeline(DataConfig(kind="lm", vocab_size=cfg.vocab_size,
                               seq_len=64, global_batch=8))
    return cfg, state, step, pipe


def test_loss_decreases():
    _, state, step, pipe = tiny_setup()
    losses = []
    for i in range(30):
        state, m = step(state, pipe.at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::10]


def test_accumulation_matches_full_batch():
    """Microbatched gradients must equal the full-batch gradient (loss and
    global grad norm agree to float tolerance; per-param comparison is
    ill-conditioned through Adam's step-1 sign normalization)."""
    cfg, state, _, pipe = tiny_setup()
    opt = adamw(lr=5e-3, weight_decay=0.0)
    s1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))
    s4 = jax.jit(make_train_step(cfg, opt, accum_steps=4))
    batch = pipe.at(0)
    st1, m1 = s1(dict(state), batch)
    st4, m4 = s4(dict(state), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(
        float(m4["grad_norm"]), rel=1e-4)
    # one more step from each: losses stay in lockstep
    st1b, m1b = s1(st1, pipe.at(1))
    st4b, m4b = s4(st4, pipe.at(1))
    assert float(m1b["loss"]) == pytest.approx(float(m4b["loss"]),
                                               rel=5e-3)


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(55)) < float(lr(20))


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(100) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(100.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    _, state, step, pipe = tiny_setup()
    state, _ = step(state, pipe.at(0))
    ck = Checkpointer(str(tmp_path))
    ck.save(state, 1)
    restored, rs = ck.restore()
    assert rs == 1
    a = jax.tree.leaves(state["params"])
    b = jax.tree.leaves(restored["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_gc_and_latest(tmp_path):
    _, state, _, _ = tiny_setup()
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(state, s)
    assert ck.steps() == [3, 4]
    assert ck.latest() == 4


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with explicit shardings (different 'mesh' = CPU single
    device here; exercises the device_put path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    _, state, _, _ = tiny_setup()
    ck = Checkpointer(str(tmp_path))
    ck.save(state, 5)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = ck.restore(shardings=sh)
    leaf = jax.tree.leaves(restored["params"])[0]
    assert leaf.sharding == NamedSharding(mesh, P())


def test_checkpoint_background_save(tmp_path):
    _, state, _, _ = tiny_setup()
    ck = Checkpointer(str(tmp_path))
    ck.save(state, 7, background=True)
    ck.wait()
    assert ck.latest() == 7


# --------------------------------------------------------- fault tolerance
def test_ft_loop_rejects_nan_steps(tmp_path):
    _, state, step, pipe = tiny_setup()
    calls = {"n": 0}

    def flaky_step(st, batch):
        calls["n"] += 1
        st, m = step(st, batch)
        if calls["n"] == 3:          # poison one step
            m = dict(m)
            m["loss"] = jnp.asarray(float("nan"))
        return st, m

    loop = FaultTolerantLoop(flaky_step, pipe,
                             Checkpointer(str(tmp_path)), ckpt_every=100,
                             log=lambda *_: None)
    state, report = loop.run(state, 0, 10)
    assert report.bad_steps == 1
    assert report.steps_run == 9


def test_ft_loop_retries_exceptions(tmp_path):
    _, state, step, pipe = tiny_setup()
    calls = {"n": 0}

    def crashy(st, batch):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("simulated device failure")
        return step(st, batch)

    loop = FaultTolerantLoop(crashy, pipe, None, log=lambda *_: None)
    state, report = loop.run(state, 0, 5)
    assert report.retries == 1
    assert report.steps_run == 5


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, alpha=0.5)
    for i in range(5):
        assert not mon.observe(i, 1.0)
    assert mon.observe(5, 5.0)           # 5x the EWMA
    assert mon.flagged == [(5, 5.0)]
    assert mon.ewma == pytest.approx(1.0)


def test_straggler_cold_start():
    """Cold-start contract: an outlier FIRST observation (the
    jit-compile-on-step-0 case) seeds the EWMA only provisionally — the
    next steady observation flags it retroactively and rebases the
    baseline, instead of folding the outlier in permanently."""
    mon = StragglerMonitor(threshold=2.0, alpha=0.1)
    assert not mon.observe(0, 10.0)      # no baseline yet: never flags
    assert not mon.observe(1, 1.0)       # steady step exposes the seed
    assert mon.flagged == [(0, 10.0)]    # …which is flagged retroactively
    assert mon.ewma == pytest.approx(1.0)   # rebased, NOT 0.9*10 + 0.1*1
    assert mon.observe(2, 5.0)           # later stragglers now visible
    assert mon.flagged == [(0, 10.0), (2, 5.0)]

    # A steady seed confirmed by a peer behaves exactly as before.
    mon2 = StragglerMonitor(threshold=2.0, alpha=0.1)
    assert not mon2.observe(0, 1.0)
    assert not mon2.observe(1, 1.1)
    assert mon2.flagged == []
    assert mon2.ewma == pytest.approx(0.9 * 1.0 + 0.1 * 1.1)


# ------------------------------------------------------ grad compression
def test_int8_quantization_roundtrip():
    x = jax.random.normal(KEY, (256,)) * 3.0
    q, scale = quantize_int8(x)
    err = dequantize_int8(q, scale) - x
    assert float(jnp.abs(err).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the *accumulated* quantization error stays
    bounded instead of growing linearly."""
    x = jax.random.normal(KEY, (128,)) * 0.01
    err = jnp.zeros_like(x)
    total_sent = jnp.zeros_like(x)
    for _ in range(50):
        q, scale, err = compress_residual(x, err)
        total_sent = total_sent + dequantize_int8(q, scale)
    # after 50 steps total transmitted ≈ 50x the true gradient
    np.testing.assert_allclose(np.asarray(total_sent),
                               np.asarray(50.0 * x), atol=0.02)


def test_compressed_allreduce_shardmap():
    from repro.train.grad_compress import make_compressed_allreduce
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    run = make_compressed_allreduce(mesh, ("data",))
    g = {"w": jnp.ones((8, 8)) * 0.5}
    e = {"w": jnp.zeros((8, 8))}
    mean, new_err = run(g, e)
    np.testing.assert_allclose(np.asarray(mean["w"]), 0.5, atol=0.01)


# ----------------------------------------------------------------- data
def test_pipeline_deterministic_and_host_sharded():
    base = dict(kind="lm", vocab_size=1000, seq_len=32, global_batch=8)
    p1 = Pipeline(DataConfig(**base, seed=1))
    p2 = Pipeline(DataConfig(**base, seed=1))
    np.testing.assert_array_equal(p1.at(7)["tokens"], p2.at(7)["tokens"])
    assert not np.array_equal(p1.at(7)["tokens"], p1.at(8)["tokens"])
    h0 = Pipeline(DataConfig(**base, seed=1, n_hosts=2, host_id=0))
    h1 = Pipeline(DataConfig(**base, seed=1, n_hosts=2, host_id=1))
    assert h0.local_batch == 4
    assert not np.array_equal(h0.at(0)["tokens"], h1.at(0)["tokens"])


def test_pipeline_kinds():
    vlm = Pipeline(DataConfig(kind="vlm", vocab_size=100, seq_len=32,
                              global_batch=2, frontend_dim=8,
                              frontend_tokens=8))
    b = vlm.at(0)
    assert b["tokens"].shape == (2, 24)
    assert b["patches"].shape == (2, 8, 8)
    audio = Pipeline(DataConfig(kind="audio", vocab_size=50, seq_len=32,
                                global_batch=2, frontend_dim=8))
    b = audio.at(0)
    assert b["frames"].shape == (2, 32, 8)
    assert b["mask"].dtype == bool
