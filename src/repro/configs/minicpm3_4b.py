"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 —
MLA (q_lora=768, kv_lora=256, nope/rope 64/32, v=64)
[hf:openbmb/MiniCPM3-4B; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, d_ff=6400, vocab_size=73448,
        n_heads=40, attn_type="mla",
        q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
        act="silu", tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return config().replace(
        name="minicpm3-smoke", n_layers=3, d_model=64, d_ff=128,
        vocab_size=250, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
        attn_chunk=32, remat=False)
