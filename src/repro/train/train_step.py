"""Train step factory: loss (per family), grad, microbatch accumulation
(compute/communication overlap knob), optimizer update.

``TrainState`` is a plain dict pytree: {"params", "opt", "step"} — no
framework dependency, shardable leaf-by-leaf via
``sharding.partition_specs``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import forward, lm_loss, masked_pred_loss
from ..models.loss import fused_lm_loss
from .optimizer import Optimizer

TrainState = dict  # {"params": pytree, "opt": {...}, "step": i32}


def init_train_state(params, optimizer: Optimizer) -> TrainState:
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def loss_for(cfg, params, batch, aux_weight: float = 0.01):
    """Training loss via the fused (chunked) CE path — (B,S,vocab) logits
    are never materialized."""
    (hidden, head), _, (aux, _) = forward(params, cfg, batch,
                                          mode="train", output="hidden")
    if cfg.is_encoder:
        loss = fused_lm_loss(hidden, head, batch["labels"],
                             mask=batch["mask"],
                             final_softcap=cfg.final_logit_softcap,
                             vocab_size=cfg.vocab_size, shift=False,
                             chunk=cfg.loss_chunk)
    elif cfg.frontend == "vision_stub":
        np_ = cfg.frontend_tokens
        loss = fused_lm_loss(hidden[:, np_:], head, batch["tokens"],
                             final_softcap=cfg.final_logit_softcap,
                             vocab_size=cfg.vocab_size,
                             chunk=cfg.loss_chunk)
    else:
        loss = fused_lm_loss(hidden, head, batch["tokens"],
                             final_softcap=cfg.final_logit_softcap,
                             vocab_size=cfg.vocab_size,
                             chunk=cfg.loss_chunk)
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def make_train_step(cfg, optimizer: Optimizer, accum_steps: int = 1,
                    accum_dtype=jnp.float32, accum_unroll: bool = False):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``accum_steps > 1`` splits the batch into microbatches scanned
    sequentially — bounds activation memory and gives XLA independent
    grad-reduce chunks to overlap with the next microbatch's compute.
    ``accum_dtype=bf16`` halves the accumulation buffer for the ≥100B
    models.
    """

    def grads_of(params, batch):
        (tot, metrics), grads = jax.value_and_grad(
            lambda p: loss_for(cfg, p, batch), has_aux=True)(params)
        return grads, metrics

    def train_step(state: TrainState, batch: dict):
        params = state["params"]
        if accum_steps == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                mb = b // accum_steps
                return x[: mb * accum_steps].reshape(
                    (accum_steps, mb) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(accum_dtype), g_acc, g)
                m_acc = jax.tree.map(lambda a, b_: a + b_, m_acc, m)
                return (g_acc, m_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            zero_m = {"loss": jnp.zeros((), jnp.float32),
                      "aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(
                acc_step, (zero_g, zero_m), micro,
                unroll=accum_steps if accum_unroll else 1)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m / accum_steps, metrics)

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {**metrics, **opt_metrics}
        return new_state, metrics

    return train_step
