"""Assigned architecture configs. ``get_config(name)`` returns the exact
published config; ``get_config(name, reduced=True)`` returns the
small-family smoke variant (same structure, tiny dims)."""
from __future__ import annotations

import importlib

ARCHS = (
    "gemma2-2b",
    "smollm-360m",
    "minicpm3-4b",
    "internlm2-20b",
    "zamba2-2.7b",
    "mixtral-8x22b",
    "deepseek-v2-236b",
    "pixtral-12b",
    "rwkv6-3b",
    "hubert-xlarge",
)


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, reduced: bool = False):
    m = _module(name)
    return m.reduced_config() if reduced else m.config()


SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_DEFS = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def runnable_cells():
    """All (arch, shape) pairs honoring the documented skips
    (DESIGN.md §4): long_500k only for sub-quadratic archs; no decode
    shapes for encoder-only archs."""
    cells = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            kind = SHAPE_DEFS[s]["kind"]
            if cfg.is_encoder and kind == "decode":
                continue
            if s == "long_500k" and not cfg.sub_quadratic:
                continue
            cells.append((a, s))
    return cells
