"""Cross-sample pipelining — paper Sec. 5.4 / Fig. 7 / Fig. 11.

Within one sample the GEMM chain is sequential, but samples of a batch are
independent, so communication of one sample can overlap computation of
another. The paper casts this as a resource-constrained project scheduling
problem (RCPSP) with two unit-capacity resources — the NoP ("comm") and the
chiplet array ("comp") — and solves it with an ILP.

Three engines (DESIGN.md §13), selected by :class:`PipelineConfig`:

  * ``engine="python"`` — the serial critical-path-first priority list
    scheduler (heapq SGS); behavioral reference.
  * ``engine="vectorized"`` (the ``"auto"`` default) — the batched SGS of
    :mod:`repro.core.pipelining_jax`: the regular job structure (every
    sample emits the same (in, comp, out) chain) makes priorities a
    reversed cumulative sum and the ready set a per-sample frontier, so
    whole (workload × batch × segment-variant) grids schedule through one
    jitted call per shape group (``backend="jax"``; ``backend="numpy"``
    runs the same frontier loop on host as the parity reference). Exact —
    bit-identical makespans/starts vs the python engine.
  * ``engine="milp"`` — the paper's time-indexed RCPSP ILP via HiGHS
    (wall-clock budgeted). The bucket solution is re-simulated through
    the SGS so the reported (makespan, starts) is a *feasible*
    continuous-time schedule covering every job.

Durations come from the evaluator's per-op (comm_in, comp, comm_out)
breakdown (optionally under ``congestion="flow"`` — see
``api.ScheduleResult.pipeline``).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

__all__ = ["Job", "build_jobs", "list_schedule", "milp_schedule",
           "sequential_makespan", "PipelineResult", "pipeline_batch",
           "PipelineConfig", "PIPELINE_ENGINES",
           "resolve_auto_pipeline_engine", "vectorized_schedule"]

COMM, COMP = "comm", "comp"

#: Scheduler engines (DESIGN.md §13). ``"auto"`` resolves to
#: ``"vectorized"`` — exact vs the python reference (bit-identical, not
#: just rtol) and batchable across sweep grids.
PIPELINE_ENGINES = ("python", "vectorized", "milp", "auto")


def resolve_auto_pipeline_engine(engine: str) -> str:
    """Resolve ``"auto"`` to a concrete scheduler engine. Mirrors
    :func:`repro.core.miqp.resolve_auto_engine`: the vectorized SGS is
    exact vs the serial reference and batches whole grids, so it wins
    everywhere."""
    if engine == "auto":
        return "vectorized"
    if engine not in PIPELINE_ENGINES:
        raise ValueError(f"unknown engine {engine!r}; "
                         f"one of {PIPELINE_ENGINES}")
    return engine


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Scheduler selection + MILP knobs (frozen → usable as a sweep-cache
    key component, like ``GAConfig``/``MIQPConfig``).

    ``backend`` applies to the vectorized engine only: ``"jax"`` runs the
    jitted batched SGS (:mod:`repro.core.pipelining_jax`), ``"numpy"``
    the host frontier loop (parity reference). ``"auto"`` resolves to
    numpy for a solo :func:`pipeline_batch` call (no jit dispatch cost)
    and to jax inside :func:`repro.core.sweep.pipeline_sweep` (grid
    batching always wins) — both produce bit-identical schedules, so the
    resolution is a pure performance choice."""

    engine: str = "auto"       # python | vectorized | milp | auto
    backend: str = "auto"      # numpy | jax | auto (vectorized engine)
    n_buckets: int = 64        # milp time-bucket count
    time_limit: float = 30.0   # milp wall-clock budget (seconds)
    devices: str = "auto"      # grid-axis execution of batched schedules:
                               # "single" | "sharded" | "auto" (DESIGN.md
                               # §15; result-neutral — never part of a
                               # cache fingerprint)


@dataclasses.dataclass
class Job:
    jid: int
    sample: int
    op: int
    kind: str          # "in" | "comp" | "out"
    dur: float
    resource: str      # COMM or COMP
    preds: list[int]


def build_jobs(segments: list[tuple[str, float, float, float]],
               batch: int) -> list[Job]:
    """``segments`` = per-op (name, t_in, t_comp, t_out) for ONE sample."""
    jobs: list[Job] = []
    for s in range(batch):
        prev = -1
        for i, (_, tin, tcomp, tout) in enumerate(segments):
            trip = [("in", tin, COMM), ("comp", tcomp, COMP),
                    ("out", tout, COMM)]
            for kind, dur, res in trip:
                preds = [prev] if prev >= 0 else []
                j = Job(len(jobs), s, i, kind, float(max(dur, 0.0)), res,
                        preds)
                jobs.append(j)
                prev = j.jid
    return jobs


def sequential_makespan(segments, batch: int) -> float:
    return batch * float(sum(t1 + t2 + t3 for _, t1, t2, t3 in segments))


def _critical_path(jobs: list[Job]) -> np.ndarray:
    """Longest path from each job to the sink (priority for the SGS)."""
    succ: dict[int, list[int]] = {j.jid: [] for j in jobs}
    for j in jobs:
        for p in j.preds:
            succ[p].append(j.jid)
    prio = np.zeros(len(jobs))
    for j in reversed(jobs):  # jobs are topologically ordered by build
        tail = max((prio[s] for s in succ[j.jid]), default=0.0)
        prio[j.jid] = j.dur + tail
    return prio


def _sgs(jobs: list[Job], prio: np.ndarray
         ) -> tuple[float, dict[int, float]]:
    """Serial schedule-generation scheme under a given priority vector:
    repeatedly dispatch the highest-priority *ready* job (predecessors
    all scheduled) at the earliest time its resource and its chain allow.

    The heap can only run dry with all jobs scheduled: every job starts
    with ``indeg == len(preds)``, the indeg-0 set seeds the heap, and
    each pop decrements its successors' indegs, pushing any that reach
    zero — Kahn's invariant, so for acyclic input some job is ready
    whenever ``done < n``. (An earlier revision kept a ``pending``
    release list for an empty-heap case that therefore cannot occur —
    and nothing ever pushed to it, so reaching it would have raised
    IndexError. ``tests/test_core_pipelining.py`` pins the invariant.)
    """
    n = len(jobs)
    indeg = {j.jid: len(j.preds) for j in jobs}
    ready_time = {j.jid: 0.0 for j in jobs}
    free = {COMM: 0.0, COMP: 0.0}
    start: dict[int, float] = {}
    done = 0
    # ready heap keyed by (-priority, jid)
    heap = [(-prio[j.jid], j.jid) for j in jobs if indeg[j.jid] == 0]
    heapq.heapify(heap)
    succ: dict[int, list[int]] = {j.jid: [] for j in jobs}
    for j in jobs:
        for p in j.preds:
            succ[p].append(j.jid)
    byid = {j.jid: j for j in jobs}
    makespan = 0.0
    while done < n:
        _, jid = heapq.heappop(heap)
        j = byid[jid]
        t0 = max(ready_time[jid], free[j.resource])
        start[jid] = t0
        t1 = t0 + j.dur
        free[j.resource] = t1
        makespan = max(makespan, t1)
        done += 1
        for s in succ[jid]:
            ready_time[s] = max(ready_time[s], t1)
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, (-prio[s], s))
    return makespan, start


def list_schedule(jobs: list[Job]) -> tuple[float, dict[int, float]]:
    """Serial schedule-generation scheme, critical-path-first."""
    return _sgs(jobs, _critical_path(jobs))


# ------------------------------------------------- vectorized engine
def _segment_durations(segments) -> np.ndarray:
    """Per-sample flattened job durations ``[3n]``, clamped like
    :func:`build_jobs` (in, comp, out per op)."""
    durs = np.array([[tin, tcomp, tout]
                     for _, tin, tcomp, tout in segments], dtype=np.float64)
    durs = durs.reshape(-1) if durs.size else np.zeros(0)
    return np.maximum(durs, 0.0)


def chain_priorities(dur_flat: np.ndarray) -> np.ndarray:
    """Critical-path priorities of one sample's job chain as a reversed
    cumulative sum — the only successor of chain job ``p`` is ``p+1``,
    so ``prio[p] = dur[p] + prio[p+1]``; ``np.cumsum`` over the reversed
    durations performs the *same* sequence of pairwise additions as the
    per-job :func:`_critical_path` walk (IEEE addition is commutative),
    so the priorities are bit-identical, and tie-breaks — which compare
    float priorities exactly — cannot diverge across engines."""
    return np.cumsum(dur_flat[::-1])[::-1].copy()


def _frontier_schedule_host(dur_flat: np.ndarray, prio: np.ndarray,
                            batch: int) -> tuple[float, np.ndarray]:
    """Host reference of the batched SGS step (DESIGN.md §13).

    Because every sample runs the same chain, the ready set is exactly
    the per-sample *frontier* (the next unscheduled chain position): a
    pop makes its chain successor ready immediately, so the heap always
    holds one entry per unfinished sample. Each step therefore dispatches
    ``argmax`` priority over the frontiers (ties → smallest jid, the
    heap's tie-break) onto its unit resource — the same pop sequence,
    and bit-identical arithmetic, as :func:`list_schedule`. Returns
    ``(makespan, starts [batch, 3n])``."""
    L = dur_flat.shape[0]
    res = np.tile(np.array([0, 1, 0], dtype=np.int64), L // 3)
    ptr = np.zeros(batch, dtype=np.int64)
    ready = np.zeros(batch, dtype=np.float64)
    free = np.zeros(2, dtype=np.float64)
    starts = np.zeros((batch, L), dtype=np.float64)
    sample_base = np.arange(batch, dtype=np.int64) * L
    for _ in range(batch * L):
        active = ptr < L
        pr = np.where(active, prio[np.minimum(ptr, L - 1)], -np.inf)
        cand = np.where(active & (pr == pr.max()), sample_base + ptr,
                        batch * L)
        s = int(np.argmin(cand))
        p = int(ptr[s])
        r = int(res[p])
        t0 = max(ready[s], free[r])
        t1 = t0 + dur_flat[p]
        starts[s, p] = t0
        free[r] = t1
        ready[s] = t1
        ptr[s] += 1
    return float(free.max(initial=0.0)), starts


def vectorized_schedule(segments, batch: int, backend: str = "numpy"
                        ) -> tuple[float, np.ndarray]:
    """Vectorized list schedule for one (segments, batch) instance:
    ``(makespan, starts [batch, 3n])`` with ``starts[s, p]`` the start of
    sample ``s``'s p-th chain job (jid ``s*3n + p`` in
    :func:`build_jobs` order). ``backend="jax"`` is the ``G=1`` case of
    :func:`repro.core.pipelining_jax.schedule_batch` — the same
    executable the sweep engine batches, so solo == batched exactly."""
    dur = _segment_durations(segments)
    if backend == "jax":
        from . import pipelining_jax

        out = pipelining_jax.schedule_batch(
            dur.reshape(1, -1, 3) if dur.size else dur.reshape(1, 0, 3),
            batch)
        return float(out["makespan"][0]), out["starts"][0]
    if backend != "numpy":
        raise ValueError(f"unknown backend {backend!r}; "
                         f"one of ('numpy', 'jax')")
    return _frontier_schedule_host(dur, chain_priorities(dur), batch)


def milp_schedule(jobs: list[Job], n_buckets: int = 64,
                  time_limit: float = 60.0
                  ) -> tuple[float, dict[int, float]]:
    """Time-indexed RCPSP MILP (the paper's ILP). Falls back to the list
    schedule if the model is too large or the solver finds nothing better.

    The returned pair is always a *feasible continuous-time schedule
    covering every job* (zero-duration jobs included): the MILP's
    bucket-quantized solution fixes a job priority order, which is
    re-simulated through the SGS — bucket rounding can violate
    continuous-time precedence/resource feasibility by up to one bucket
    width, so the raw ``res.x[cmax] * dt`` objective is a bound, not a
    schedule."""
    import scipy.sparse as sp
    from scipy.optimize import Bounds, LinearConstraint, milp

    ub_makespan, greedy_start = list_schedule(jobs)
    if ub_makespan <= 0:
        return ub_makespan, greedy_start
    active = [j for j in jobs if j.dur > 0]
    if len(active) * n_buckets > 60000:
        return ub_makespan, greedy_start
    dt = ub_makespan / n_buckets
    d = {j.jid: max(1, int(np.ceil(j.dur / dt))) for j in active}
    H = n_buckets + max(d.values())

    nv = 0
    var = {}
    for j in active:
        for t in range(H - d[j.jid] + 1):
            var[j.jid, t] = nv
            nv += 1
    cmax = nv
    nv += 1

    rows, lo, hi = [], [], []

    def add(idx, coef, l, h):
        rows.append((idx, coef))
        lo.append(l)
        hi.append(h)

    for j in active:
        ids = [var[j.jid, t] for t in range(H - d[j.jid] + 1)]
        add(ids, [1.0] * len(ids), 1.0, 1.0)
        # makespan
        add([cmax] + ids,
            [1.0] + [-(t + d[j.jid]) for t in range(len(ids))], 0.0, np.inf)

    # precedence (pred may be zero-duration → collapse to nearest active)
    startexpr = {}
    for j in active:
        startexpr[j.jid] = ([var[j.jid, t]
                             for t in range(H - d[j.jid] + 1)],
                            list(range(H - d[j.jid] + 1)))
    act_ids = {j.jid for j in active}
    byid = {j.jid: j for j in jobs}

    def resolve_pred(p):  # walk through zero-duration predecessors
        stack = [p]
        out = []
        while stack:
            q = stack.pop()
            if q in act_ids:
                out.append(q)
            else:
                stack.extend(byid[q].preds)
        return out

    for j in active:
        for p in j.preds:
            for q in resolve_pred(p):
                ji, jc = startexpr[j.jid]
                qi, qc = startexpr[q]
                add(ji + qi, [float(c) for c in jc] + [-float(c) for c in qc],
                    float(d[q]), np.inf)

    # resource capacity per bucket
    for res in (COMM, COMP):
        members = [j for j in active if j.resource == res]
        for tau in range(H):
            idx = []
            for j in members:
                for t in range(max(0, tau - d[j.jid] + 1),
                               min(tau, H - d[j.jid]) + 1):
                    idx.append(var[j.jid, t])
            if len(idx) > 1:
                add(idx, [1.0] * len(idx), -np.inf, 1.0)

    data, ri, ci = [], [], []
    for r, (idx, coef) in enumerate(rows):
        for jj, a in zip(idx, coef):
            ri.append(r)
            ci.append(jj)
            data.append(a)
    A = sp.csr_matrix((data, (ri, ci)), shape=(len(rows), nv))
    c = np.zeros(nv)
    c[cmax] = 1.0
    integrality = np.ones(nv, dtype=int)
    integrality[cmax] = 0
    res = milp(c=c,
               constraints=LinearConstraint(A, np.array(lo), np.array(hi)),
               integrality=integrality,
               bounds=Bounds(np.zeros(nv),
                             np.concatenate([np.ones(nv - 1), [np.inf]])),
               options={"time_limit": time_limit, "presolve": True})
    if res.x is None:
        return ub_makespan, greedy_start

    # Bucket starts for active jobs; zero-duration jobs sit at their
    # resolved predecessor finish (topological fill — build order is
    # topological), so the priority order below covers EVERY job.
    bucket_start: dict[int, float] = {}
    for (jid, t), v in var.items():
        if res.x[v] > 0.5:
            bucket_start[jid] = t * dt
    for j in jobs:
        if j.jid not in bucket_start:
            bucket_start[j.jid] = max(
                (bucket_start[p] + byid[p].dur for p in j.preds),
                default=0.0)

    # Re-simulate the MILP's job order through the SGS: the certified
    # continuous-time schedule (the bucket objective is only a bound).
    order = sorted(bucket_start, key=lambda jid: (bucket_start[jid], jid))
    prio = np.zeros(len(jobs))
    for rank, jid in enumerate(order):
        prio[jid] = float(len(order) - rank)
    ms_sim, starts_sim = _sgs(jobs, prio)
    if ms_sim >= ub_makespan:
        return ub_makespan, greedy_start
    return ms_sim, starts_sim


@dataclasses.dataclass
class PipelineResult:
    batch: int
    sequential: float
    pipelined: float
    engine: str = "python"     # resolved scheduler engine (DESIGN.md §13)

    @property
    def speedup(self) -> float:
        return self.sequential / self.pipelined if self.pipelined > 0 else 1.0

    @property
    def per_sample(self) -> float:
        return self.pipelined / self.batch


def pipeline_batch(segments, batch: int, use_milp: bool = False,
                   time_limit: float = 30.0,
                   config: PipelineConfig | None = None) -> PipelineResult:
    """Schedule one (segments, batch) pipelining instance.

    ``config`` selects the engine (DESIGN.md §13); ``use_milp=True`` is
    the legacy spelling of ``PipelineConfig(engine="milp")``. Batched
    grids should go through :func:`repro.core.sweep.pipeline_sweep`
    instead — one compiled call per (n_ops, batch) shape group."""
    cfg = config or PipelineConfig()
    if use_milp:
        cfg = dataclasses.replace(cfg, engine="milp", time_limit=time_limit)
    engine = resolve_auto_pipeline_engine(cfg.engine)
    if engine == "milp":
        ms, _ = milp_schedule(build_jobs(segments, batch),
                              n_buckets=cfg.n_buckets,
                              time_limit=cfg.time_limit)
    elif engine == "python":
        ms, _ = list_schedule(build_jobs(segments, batch))
    else:
        backend = "numpy" if cfg.backend == "auto" else cfg.backend
        ms, _ = vectorized_schedule(segments, batch, backend=backend)
    return PipelineResult(batch, sequential_makespan(segments, batch), ms,
                          engine=engine)
