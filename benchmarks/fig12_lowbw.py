"""Fig. 12 reproduction: low-bandwidth (DRAM) 4×4 type-A systems.

Paper claims: GA/MIQP latency speedups of 40%/72% over LS (EDP 28%/37%),
with the GA–MIQP gap *wider* than the HBM case (off-chip congestion
simplifies the on-chip scheduling space, so MIQP solves closer to
optimal within its budget).

Grid driving (benchmarks/README.md): per-workload LS references come
from one batched sweep, then get the same batch-4 pipelining treatment
as the solver rows (the co-search scores pipelined makespans, so the LS
side must too — see benchmarks/README.md for this semantic change); the
old per-(objective × workload) GA grid is replaced by ONE batched
Pareto-front ``sweep.cosearch_sweep`` (DESIGN.md §16) whose front
serves BOTH objective readings per workload from a single search; the
MIQP grid runs batched lattice solves through
``sweep.solve_grid(method="miqp")`` (DESIGN.md §12) plus the per-point
polish and one batched scoring sweep per objective.
"""
from __future__ import annotations

import time

from repro.core import (CoSearchConfig, EvalOptions, make_hw,
                        refine_schedule, sweep)
from repro.core.miqp import MIQPConfig
from repro.core.sweep import PipelinePoint
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json

# same budget envelope as the old per-pass GA_CFG
# (GAConfig(generations=60, population=64)); batch matches the
# pipelined references below.
CO_CFG = CoSearchConfig(generations=60, population=64, batch=4)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)
MIQP_OPTS = EvalOptions(redistribution=True, async_exec=True)
MIQP_SOLVE_OPTS = EvalOptions(redistribution=True, async_exec=False)
BATCH = 4


def main(fast: bool = False, backend: str = "jax"):
    hw = make_hw("A", 4, "dram")
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    opts = EvalOptions(redistribution=True, async_exec=True)

    # LS references, pipelined at the co-search's batch: the LS
    # partition's per-op segments through one batched pipeline_sweep,
    # latency = pipelined makespan / batch, EDP = energy × that.
    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[w], hw) for w in wnames], backend=backend)
    base_pipes = sweep.pipeline_sweep(
        [PipelinePoint(
            [(f"op{i}", float(r["t_in"][i]), float(r["t_comp"][i]),
              float(r["t_out"][i])) for i in range(len(tasks[w]))],
            BATCH)
         for w, r in zip(wnames, base_recs)],
        backend=backend)
    ref = {}
    for w, r, p in zip(wnames, base_recs, base_pipes):
        lat = p.pipelined / BATCH
        ref[w] = {"latency": lat, "edp": r["energy"] * lat}

    results = {}
    sp = {(o, m): [] for o in ("latency", "edp")
          for m in ("ga", "miqp")}

    # ---- fused co-search (DESIGN.md §16): ONE batched call; the
    # Pareto front's min-EDP and min-latency rows serve both objective
    # readings (the old flow ran a separate GA pass per objective).
    t0 = time.perf_counter()
    co_recs = sweep.cosearch_sweep(
        [sweep.EvalPoint(tasks[w], hw, opts) for w in wnames],
        "edp", CO_CFG, backend=backend)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig12/cosearch/sweep_total", us, f"{len(wnames)} points")
    for w, r in zip(wnames, co_recs):
        for o, val in (("latency", float(r.front["latency"].min())),
                       ("edp", r.edp)):
            s = ref[w][o] / val
            sp[(o, "ga")].append(s)
            results[f"{o}/{w}/ga"] = s
            emit(f"fig12/{o}/{w}/cosearch", 0.0, f"speedup={s:.3f}x")

    # MIQP: batched lattice solves + polish + batched scoring
    # (DESIGN.md §12) — the optimize(method="miqp") pipeline.
    hw_diag = hw.replace(diagonal_links=True)
    for o in ("latency", "edp"):
        pts = [sweep.EvalPoint(tasks[w], hw_diag, MIQP_SOLVE_OPTS)
               for w in wnames]
        t0 = time.perf_counter()
        mi_recs = sweep.solve_grid(pts, o, MIQP_CFG, backend=backend,
                                   method="miqp")
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig12/{o}/miqp/solve_grid_total", us, f"{len(pts)} points")
        polished = [refine_schedule(pt.task, pt.hw, MIQP_OPTS, r.partition,
                                    r.redist_mask, o, backend=backend)
                    for pt, r in zip(pts, mi_recs)]
        score = sweep.eval_sweep(
            [sweep.EvalPoint(pt.task, pt.hw, MIQP_OPTS, partition=part,
                             redist_mask=rd)
             for pt, (part, rd) in zip(pts, polished)],
            backend=backend)
        # same batch-4 pipelining treatment as the LS references and
        # the co-search rows — one batched pipeline_sweep per objective.
        mi_pipes = sweep.pipeline_sweep(
            [PipelinePoint(
                [(f"op{i}", float(rec["t_in"][i]),
                  float(rec["t_comp"][i]), float(rec["t_out"][i]))
                 for i in range(len(tasks[w]))], BATCH)
             for w, rec in zip(wnames, score)],
            backend=backend)
        for wname, rec, p in zip(wnames, score, mi_pipes):
            lat = p.pipelined / BATCH
            val = lat if o == "latency" else rec["energy"] * lat
            s = ref[wname][o] / val
            sp[(o, "miqp")].append(s)
            results[f"{o}/{wname}/miqp"] = s
            emit(f"fig12/{o}/{wname}/miqp", 0.0, f"speedup={s:.3f}x")

    for o in ("latency", "edp"):
        for m in ("ga", "miqp"):
            emit(f"fig12/{o}/geomean/{m}", 0.0,
                 f"{(geomean(sp[(o, m)]) - 1) * 100:+.1f}% vs LS")
    save_json("fig12", results)


if __name__ == "__main__":
    main()
