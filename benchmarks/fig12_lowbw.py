"""Fig. 12 reproduction: low-bandwidth (DRAM) 4×4 type-A systems.

Paper claims: GA/MIQP latency speedups of 40%/72% over LS (EDP 28%/37%),
with the GA–MIQP gap *wider* than the HBM case (off-chip congestion
simplifies the on-chip scheduling space, so MIQP solves closer to
optimal within its budget).

Grid driving (benchmarks/README.md): per-workload LS references come
from one batched sweep (latency + EDP from the same records); the
(objective × workload) GA grid runs via ``sweep.run_grid``; the MIQP
grid runs batched lattice solves through
``sweep.solve_grid(method="miqp")`` (DESIGN.md §12) plus the per-point
polish and one batched scoring sweep per objective.
"""
from __future__ import annotations

import time

from repro.core import EvalOptions, make_hw, optimize, refine_schedule, sweep
from repro.core.ga import GAConfig
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, geomean, save_json

GA_CFG = GAConfig(generations=60, population=64)
MIQP_CFG = MIQPConfig(time_limit=60, edp_sweep=3)
MIQP_OPTS = EvalOptions(redistribution=True, async_exec=True)
MIQP_SOLVE_OPTS = EvalOptions(redistribution=True, async_exec=False)


def main(fast: bool = False, backend: str = "jax"):
    hw = make_hw("A", 4, "dram")
    wnames = ("alexnet", "hydranet") if fast else tuple(WORKLOADS)
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}

    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[w], hw) for w in wnames], backend=backend)
    ref = dict(zip(wnames, base_recs))

    results = {}
    sp = {(o, m): [] for o in ("latency", "edp")
          for m in ("ga", "miqp")}

    def solve(objective, wname):
        return optimize(tasks[wname], hw, "ga", objective,
                        backend=backend, ga_config=GA_CFG)

    def report(pt, r, us):
        o, wname = pt["objective"], pt["wname"]
        val = r.latency if o == "latency" else r.edp
        s = ref[wname][o] / val
        sp[(o, "ga")].append(s)
        results[f"{o}/{wname}/ga"] = s
        emit(f"fig12/{o}/{wname}/ga", us, f"speedup={s:.3f}x")

    sweep.run_grid(
        sweep.grid(objective=("latency", "edp"), wname=wnames),
        solve, emit=report)

    # MIQP: batched lattice solves + polish + batched scoring
    # (DESIGN.md §12) — the optimize(method="miqp") pipeline.
    hw_diag = hw.replace(diagonal_links=True)
    for o in ("latency", "edp"):
        pts = [sweep.EvalPoint(tasks[w], hw_diag, MIQP_SOLVE_OPTS)
               for w in wnames]
        t0 = time.perf_counter()
        mi_recs = sweep.solve_grid(pts, o, MIQP_CFG, backend=backend,
                                   method="miqp")
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig12/{o}/miqp/solve_grid_total", us, f"{len(pts)} points")
        polished = [refine_schedule(pt.task, pt.hw, MIQP_OPTS, r.partition,
                                    r.redist_mask, o, backend=backend)
                    for pt, r in zip(pts, mi_recs)]
        score = sweep.eval_sweep(
            [sweep.EvalPoint(pt.task, pt.hw, MIQP_OPTS, partition=part,
                             redist_mask=rd)
             for pt, (part, rd) in zip(pts, polished)],
            backend=backend)
        for wname, rec in zip(wnames, score):
            s = ref[wname][o] / rec[o]
            sp[(o, "miqp")].append(s)
            results[f"{o}/{wname}/miqp"] = s
            emit(f"fig12/{o}/{wname}/miqp", 0.0, f"speedup={s:.3f}x")

    for o in ("latency", "edp"):
        for m in ("ga", "miqp"):
            emit(f"fig12/{o}/geomean/{m}", 0.0,
                 f"{(geomean(sp[(o, m)]) - 1) * 100:+.1f}% vs LS")
    save_json("fig12", results)


if __name__ == "__main__":
    main()
