"""Distribution layer: logical-axis sharding rules, per-arch partition
specs, and the MCMComm-driven layout planner."""
