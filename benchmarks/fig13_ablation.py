"""Fig. 13 reproduction: ablation of the co-design features.

Paper claims: partition-only optimization gives a relatively small
speedup; adding diagonal links unlocks most of the gain (bypassing
collection congestion + flattening memory-latency non-uniformity);
pipelining adds further latency gains on top.

Grid driving (benchmarks/README.md): LS references come from the batched
sweep; partition × diagonal-links × pipeline-segmentation are searched
JOINTLY by the fused co-search (``sweep.cosearch_sweep``, DESIGN.md §16
— the link config and segment boundaries are genes, so the old
GA-per-link-variant grid and the separate ``pipeline_sweep`` layering
pass collapse into one batched Pareto-front call). The cumulative
ablation readings (partition → +diagonal → +pipelining) come from
re-scoring the joint genome with each feature switched off — same
feature axes as before, one search instead of three passes. The MIQP
ablation grid is unchanged: batched lattice solves through
``sweep.solve_grid(method="miqp")`` (DESIGN.md §12), polish + one
batched scoring sweep.
"""
from __future__ import annotations

import time

from repro.core import (CoSearchConfig, EvalOptions, make_hw,
                        refine_schedule, sweep)
from repro.core.miqp import MIQPConfig
from repro.graphs import WORKLOADS

from .common import emit, save_json

# population/generation budget matches the old per-variant GA_CFG
# (GAConfig(generations=60, population=64)) — the co-search covers both
# link variants AND segmentation inside that same budget.
CO_CFG = CoSearchConfig(generations=60, population=64, batch=4)
MIQP_CFG = MIQPConfig()        # engine="auto" → batched lattice solves
MIQP_SOLVE_OPTS = EvalOptions(redistribution=True, async_exec=False)


def main(fast: bool = False, backend: str = "jax"):
    results = {}
    wnames = ("alexnet", "hydranet") if fast else ("alexnet", "vit",
                                                   "hydranet")
    tasks = {w: WORKLOADS[w](batch=1) for w in wnames}
    hw_plain = make_hw("A", 4, "hbm")
    hw_diag = make_hw("A", 4, "hbm", diagonal_links=True)
    opts = EvalOptions(redistribution=True, async_exec=True)

    base_recs = sweep.eval_sweep(
        [sweep.EvalPoint(tasks[w], hw_plain) for w in wnames],
        backend=backend)
    base = {w: r["latency"] for w, r in zip(wnames, base_recs)}

    # ---- fused co-search (DESIGN.md §16): ONE batched Pareto-front
    # call per workload shape covers what used to be the GA-per-link-
    # variant grid plus the pipelining pass — link config and segment
    # boundaries are genes.
    t0 = time.perf_counter()
    co_recs = sweep.cosearch_sweep(
        [sweep.EvalPoint(tasks[w], hw_plain, opts) for w in wnames],
        "latency", CO_CFG, backend=backend)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig13/cosearch/sweep_total", us, f"{len(wnames)} points")
    co = dict(zip(wnames, co_recs))

    # cumulative ablation readings, re-scored from the ONE joint genome:
    # partition-only = the genome's partition on the plain mesh,
    # +diagonal = same partition on its chosen mesh, +pipelining = the
    # full joint result (its latency already includes the batch-4
    # pipelined makespan of its chosen segmentation).
    ab_pts = []
    for w in wnames:
        r = co[w]
        hw_best = hw_diag if r.diagonal else hw_plain
        ab_pts.append(sweep.EvalPoint(tasks[w], hw_plain, opts,
                                      partition=r.partition,
                                      redist_mask=r.redist_mask))
        ab_pts.append(sweep.EvalPoint(tasks[w], hw_best, opts,
                                      partition=r.partition,
                                      redist_mask=r.redist_mask))
    ab_recs = sweep.eval_sweep(ab_pts, backend=backend)
    ablate = {w: (ab_recs[2 * i]["latency"], ab_recs[2 * i + 1]["latency"])
              for i, w in enumerate(wnames)}

    # ---- MIQP on the ablation grid (DESIGN.md §12): batched
    # lattice solves (plain + diagonal variants share shape signatures,
    # so they land in one compiled call per workload shape), then
    # polish + one batched scoring sweep — the optimize(method="miqp")
    # pipeline.
    variants = ("partition_only", "plus_diagonal")
    pts_grid = sweep.grid(wname=wnames, variant=variants)
    mi_pts = [sweep.EvalPoint(
                  tasks[p["wname"]],
                  hw_plain if p["variant"] == "partition_only" else hw_diag,
                  MIQP_SOLVE_OPTS)
              for p in pts_grid]
    t0 = time.perf_counter()
    mi_recs = sweep.solve_grid(mi_pts, "latency", MIQP_CFG,
                               backend=backend, method="miqp")
    us = (time.perf_counter() - t0) * 1e6
    emit("fig13/miqp/solve_grid_total", us, f"{len(mi_pts)} points")
    polished = [refine_schedule(pt.task, pt.hw, opts, r.partition,
                                r.redist_mask, "latency", backend=backend)
                for pt, r in zip(mi_pts, mi_recs)]
    mi_score = sweep.eval_sweep(
        [sweep.EvalPoint(pt.task, pt.hw, opts, partition=part,
                         redist_mask=rd)
         for pt, (part, rd) in zip(mi_pts, polished)],
        backend=backend)
    mi_out = {}
    for p, rec in zip(pts_grid, mi_score):
        w, v = p["wname"], p["variant"]
        mi_out[(w, v)] = base[w] / rec["latency"]
        emit(f"fig13/{w}/{v}/miqp", 0.0, f"{mi_out[(w, v)]:.3f}x")

    # ---- readings: cumulative feature speedups from the joint genome
    # + the full Pareto front per workload (EDP × latency × energy rows
    # with per-row link/segmentation genes).
    for wname in wnames:
        r = co[wname]
        lat_plain, lat_best = ablate[wname]
        part_sp = base[wname] / lat_plain
        diag_sp = base[wname] / lat_best
        pipe_sp = base[wname] / r.latency
        results[wname] = {"partition": part_sp, "diag": diag_sp,
                          "pipe": pipe_sp,
                          "cosearch_diag": bool(r.diagonal),
                          "cosearch_segments":
                              int(r.seg_mask.sum()) + 1,
                          "front": {
                              "edp": r.front["edp"].tolist(),
                              "latency": r.front["latency"].tolist(),
                              "energy": r.front["energy"].tolist(),
                              "diag": r.front["diag"].tolist(),
                          },
                          "miqp_partition": mi_out[(wname,
                                                    "partition_only")],
                          "miqp_diag": mi_out[(wname, "plus_diagonal")]}
        emit(f"fig13/{wname}/partition_only", 0.0, f"{part_sp:.3f}x")
        emit(f"fig13/{wname}/plus_diagonal", 0.0, f"{diag_sp:.3f}x")
        emit(f"fig13/{wname}/plus_pipelining", 0.0, f"{pipe_sp:.3f}x")
    save_json("fig13", results)


if __name__ == "__main__":
    main()
