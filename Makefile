# Convenience targets; everything runs with PYTHONPATH=src (no install).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test smoke bench-fast bench-smoke bench-compare ga-fitness \
	ga-evolve netsim miqp-solve pipeline-schedule opt-serve \
	sweep-shard cosearch planner-validate bench-smoke-validate cov \
	hetero quickstart

# Tier-1 verify — the command CI and the roadmap pin.
test:
	$(PY) -m pytest -x -q

# Fast gate: environment sanity (imports, optional-hypothesis shim) +
# the core evaluator / backend-parity / sweep / GA-engine suites, then
# the tiny-profile ga_evolve benchmark as a no-regression smoke check.
# Catches the class of failure where a missing dev dependency breaks
# test collection, or an engine change breaks the benchmark driver.
smoke:
	$(PY) -m pytest -x -q tests/test_core_evaluator.py \
	    tests/test_backend_parity.py tests/test_core_sweep.py \
	    tests/test_core_api.py tests/test_core_ga_engines.py \
	    tests/test_cache_store.py tests/test_serve_optserver.py \
	    tests/test_sweep_checkpoint.py
	$(MAKE) bench-smoke

bench-fast:
	$(PY) -m benchmarks.run

# Tiny-profile end-to-end benchmarks (seconds, not minutes) — smoke
# check that the GA engines + solve_grid, the netsim backends, the
# MIQP engines (milp/lattice parity), the pipelining engines
# (python/vectorized exact-parity gate), the optimization server
# (solo==served bitwise parity gate), the sharded sweep fabric
# (single==sharded bitwise parity gate on 8 forced virtual devices),
# and the planner measured-vs-predicted validation gate (calibrated
# evaluator vs dryrun cost analysis; exits nonzero above the pinned
# tolerance even in smoke mode), and the heterogeneous-hardware
# migration gate (scalar==broadcast bitwise across every engine family
# + multi-tenant never losing to even split; exits nonzero even in
# smoke mode) still run and write artifacts.
bench-smoke:
	$(PY) -m benchmarks.perf_iterations --cell ga_evolve --smoke
	$(PY) -m benchmarks.perf_iterations --cell netsim --smoke
	$(PY) -m benchmarks.perf_iterations --cell miqp_solve --smoke
	$(PY) -m benchmarks.perf_iterations --cell pipeline_schedule --smoke
	$(PY) -m benchmarks.perf_iterations --cell opt_serve --smoke
	$(PY) -m benchmarks.perf_iterations --cell sweep_shard --smoke
	$(PY) -m benchmarks.perf_iterations --cell cosearch --smoke
	$(PY) -m benchmarks.perf_iterations --cell planner_validate --smoke
	$(PY) -m benchmarks.perf_iterations --cell hetero --smoke

# Verdict-regression gate: diff benchmarks/artifacts/*.json against the
# committed baselines (benchmarks/baselines/verdicts.json); exits
# nonzero on any confirmed→refuted transition. Rebase after an honest
# re-run with: make bench-compare COMPARE_FLAGS=--update
COMPARE_FLAGS ?=
bench-compare:
	$(PY) -m benchmarks.bench_compare $(COMPARE_FLAGS)

# Backend shootout for the GA fitness hot loop (DESIGN.md §8).
ga-fitness:
	$(PY) -m benchmarks.perf_iterations --cell ga_fitness

# End-to-end GA engine shootout — evolution loop included (DESIGN.md §10).
ga-evolve:
	$(PY) -m benchmarks.perf_iterations --cell ga_evolve

# Flow-simulator backend shootout on the Fig. 3 grid (DESIGN.md §11).
netsim:
	$(PY) -m benchmarks.perf_iterations --cell netsim

# MIQP engine shootout + exact-parity audit (DESIGN.md §12).
miqp-solve:
	$(PY) -m benchmarks.perf_iterations --cell miqp_solve

# RCPSP pipelining engine shootout + exact-parity gate (DESIGN.md §13).
pipeline-schedule:
	$(PY) -m benchmarks.perf_iterations --cell pipeline_schedule

# Optimization server: serial per-request solves vs the coalescing
# OptServer, with a bitwise solo==served parity gate (DESIGN.md §14).
opt-serve:
	$(PY) -m benchmarks.perf_iterations --cell opt_serve

# Sharded sweep fabric: single-device vs shard_map sweeps over 8 forced
# virtual devices, with a bitwise single==sharded parity gate
# (DESIGN.md §15). Override the count: make sweep-shard DEVICES=16.
DEVICES ?= 8
sweep-shard:
	$(PY) -m benchmarks.perf_iterations --cell sweep_shard \
	    --devices $(DEVICES)

# Fused cross-layer co-search vs the sequential per-pass flow, with
# dominance / bitwise-parity / gradient-seeding gates (DESIGN.md §16).
cosearch:
	$(PY) -m benchmarks.perf_iterations --cell cosearch

# Measured-vs-predicted validation gate: kernel-calibrated analytical
# evaluator vs executed-plan dryrun cost analysis over the model zoo
# (DESIGN.md §17). Exits nonzero above the pinned tolerances.
planner-validate:
	$(PY) -m benchmarks.perf_iterations --cell planner_validate

# Just the validation gate, smoke profile — the per-leg CI entry.
bench-smoke-validate:
	$(PY) -m benchmarks.perf_iterations --cell planner_validate --smoke

# Heterogeneous-hardware migration gate + multi-tenant placement
# (DESIGN.md §18): scalar==broadcast bitwise across every engine
# family, hetero batching speedup, search vs even split.
hetero:
	$(PY) -m benchmarks.perf_iterations --cell hetero

# Coverage smoke: tier-1 suite under pytest-cov with a floor on the
# planner-loop modules (sharding/ + kernels/calibrate.py), report-only
# elsewhere (scripts/coverage_gate.py). Skips gracefully when pytest-cov
# is not installed (it is optional in requirements-dev.txt).
cov:
	@$(PY) -c "import pytest_cov" 2>/dev/null \
	    || { echo "cov: pytest-cov not installed; skipping"; exit 0; } \
	    && $(PY) -m pytest -x -q --cov=repro \
	        --cov-report=json:coverage.json --cov-report=term \
	    && $(PY) scripts/coverage_gate.py

quickstart:
	$(PY) examples/quickstart.py
