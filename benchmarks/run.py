"""Run every benchmark (one per paper table/figure + the roofline table).

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # full (slow)
    PYTHONPATH=src python -m benchmarks.run --fast     # reduced sweep
    PYTHONPATH=src python -m benchmarks.run --backend numpy   # reference

All figure scripts drive their grids through :mod:`repro.core.sweep`:
LS baselines are evaluated in batched compiled calls and cached
process-wide, so figures sharing workloads (fig8/fig9/fig12) never
re-evaluate a baseline. ``--backend`` picks the evaluator engine
(DESIGN.md §8); numpy is the bit-identical reference path.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full workload sweep (~60+ min); default is the "
                         "bounded profile — the full-sweep outputs are "
                         "archived in benchmarks/artifacts/")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (fig3,fig8,fig9_10,"
                         "fig11,fig12,fig13,fig_hetero,roofline)")
    ap.add_argument("--backend", default="jax", choices=("numpy", "jax"),
                    help="execution backend for baselines + GA fitness "
                         "+ the fig3 netsim sweep (DESIGN.md §8/§11); "
                         "backends agree to float64 round-off (rtol "
                         "1e-9), jax is faster on large sweeps")
    args = ap.parse_args()

    args.fast = not args.full
    be = args.backend
    from repro.core import sweep

    from . import (fig3_motivation, fig8_latency_hbm, fig9_10_scaling,
                   fig11_pipelining, fig12_lowbw, fig13_ablation,
                   fig_hetero, roofline)

    benches = {
        "fig3": lambda: fig3_motivation.main(backend=be),
        "fig8": lambda: fig8_latency_hbm.main(fast=args.fast, backend=be),
        "fig9_10": lambda: fig9_10_scaling.main(fast=args.fast, backend=be),
        "fig11": lambda: fig11_pipelining.main(fast=args.fast, backend=be),
        "fig12": lambda: fig12_lowbw.main(fast=args.fast, backend=be),
        "fig13": lambda: fig13_ablation.main(fast=args.fast, backend=be),
        "fig_hetero": lambda: fig_hetero.main(fast=args.fast, backend=be),
        "roofline": lambda: roofline.main(),
    }
    only = args.only.split(",") if args.only else list(benches)
    failed = []
    prev = sweep.cache_stats()
    for name in only:
        print(f"# ===== {name} =====")
        try:
            benches[name]()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        # Per-figure cache effectiveness: hits/misses this figure added
        # on top of the process-wide sweep cache (eval + solver records).
        cur = sweep.cache_stats()
        print(f"# {name}: sweep cache +{cur['hits'] - prev['hits']} hits "
              f"/ +{cur['misses'] - prev['misses']} misses")
        prev = cur
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    total = sweep.cache_stats()
    print(f"# sweep cache totals: {total['hits']} hits / "
          f"{total['misses']} misses")
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
