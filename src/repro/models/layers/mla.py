"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3) — absorbed form.

The KV cache stores only the compressed latent ``c_kv`` (kv_lora_rank) and
the decoupled RoPE key (qk_rope_dim): the paper's memory saving. Scores
are computed in the latent space by absorbing W^UK into the query
("absorbed" inference form), making attention effectively MQA with
k-dim = kv_lora + rope and v-dim = kv_lora:

    q_abs = q_nope · W^UK          (B,S,H,kv_lora)
    score = (q_abs·c_kv + q_rope·k_rope) / sqrt(qk_nope + qk_rope)
    ctx   = softmax(score) · c_kv  (B,S,H,kv_lora)
    out   = (ctx · W^UV) · W^O
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels.flash_attention.blockwise import blockwise_attention
from ...sharding.logical import shard
from .common import dense_init, rms_norm, rope

NEG_INF = -2.0e38


def init_mla(key, cfg, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if r_q:
        p["wq_a"] = dense_init(ks[0], (D, r_q), D, dtype)
        p["q_a_norm"] = jnp.zeros((r_q,), dtype)
        p["wq_b"] = dense_init(ks[1], (r_q, H, dn + dr), r_q, dtype)
    else:
        p["wq"] = dense_init(ks[1], (D, H, dn + dr), D, dtype)
    p["wkv_a"] = dense_init(ks[2], (D, r_kv + dr), D, dtype)
    p["kv_a_norm"] = jnp.zeros((r_kv,), dtype)
    p["wk_b"] = dense_init(ks[3], (r_kv, H, dn), r_kv, dtype)
    p["wv_b"] = dense_init(ks[4], (r_kv, H, dv), r_kv, dtype)
    p["wo"] = dense_init(ks[5], (H, dv, D), H * dv, dtype)
    return p


def init_mla_cache(cfg, batch: int, capacity: int, dtype):
    return {
        "ckv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, capacity, cfg.qk_rope_dim), dtype),
    }


def _latents(p, x, cfg, positions, dtype):
    """q_abs (B,S,H,r_kv), q_rope (B,S,H,dr), c_kv (B,S,r_kv),
    k_rope (B,S,dr)."""
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        qa = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dtype))
        qa = rms_norm(qa, p["q_a_norm"], cfg.norm_eps, plus_one=True)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"].astype(dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    # absorb W^UK into the query
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, p["wk_b"].astype(dtype))
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dtype))
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], p["kv_a_norm"],
                    cfg.norm_eps, plus_one=True)
    k_rope = kv[..., cfg.kv_lora_rank:]
    k_rope = rope(k_rope[:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0, :]
    return q_abs, q_rope, c_kv, k_rope


def mla_apply(p, x, cfg, *, positions, cache=None, pos=None, mode="train",
              dtype=jnp.bfloat16):
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank
    scale = 1.0 / jnp.sqrt(float(dn + dr))
    x = x.astype(dtype)
    q_abs, q_rope, c_kv, k_rope = _latents(p, x, cfg, positions, dtype)

    new_cache = cache
    if mode in ("train", "prefill"):
        if mode == "prefill":
            ckv = jax.lax.dynamic_update_slice(
                cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, 0, 0))
            krp = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope.astype(cache["krope"].dtype),
                (0, 0, 0))
            new_cache = {"ckv": shard(ckv, "cache_mla"), "krope": krp}
        # MQA in latent space: k = [c_kv, k_rope] (KV=1), v = c_kv.
        q_cat = jnp.concatenate([q_abs, jnp.broadcast_to(
            q_rope, (B, S, H, dr))], axis=-1)
        k_cat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
        ctx = blockwise_attention(
            q_cat, k_cat, c_kv[:, :, None, :], causal=True,
            q_chunk=cfg.attn_chunk, kv_chunk=2 * cfg.attn_chunk,
            scale=scale)
    elif mode == "decode":
        capacity = cache["ckv"].shape[1]
        slot = jnp.mod(pos, capacity).astype(jnp.int32)
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, slot, 0))
        krp = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype),
            (0, slot, 0))
        new_cache = {"ckv": shard(ckv, "cache_mla"), "krope": krp}
        abs_pos = pos - jnp.mod(pos - jnp.arange(capacity), capacity)
        valid = (abs_pos >= 0) & (abs_pos <= pos)
        s = (jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(jnp.float32),
                        ckv.astype(jnp.float32))
             + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                          krp.astype(jnp.float32))) * scale
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        pr = jnp.exp(s - s.max(axis=-1, keepdims=True))
        pr = pr / pr.sum(axis=-1, keepdims=True)
        ctx = jnp.einsum("bhqs,bsr->bqhr", pr, ckv.astype(jnp.float32)
                         ).astype(dtype)
    else:
        raise ValueError(mode)

    v = jnp.einsum("bshr,rhv->bshv", ctx.astype(dtype),
                   p["wv_b"].astype(dtype))
    out = jnp.einsum("bshv,hvd->bsd", v, p["wo"].astype(dtype))
    return shard(out, "act_btd"), new_cache
