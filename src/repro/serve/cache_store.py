"""Versioned on-disk persistence for the sweep result cache (DESIGN.md
§14).

The process-wide cache in :mod:`repro.core.sweep` is keyed by exact
content fingerprints (backend + task ops + HWConfig + options +
partition/segment bytes + method-tagged solver configs — the §9/§10/§12/
§13 axes), so its entries are portable across processes: a persisted
key either matches a future request exactly or misses. This module
stores ``{fingerprint: record}`` snapshots in a crash-safe,
append-friendly file so a long-running optimization server
(:mod:`repro.serve.optserver`) can resume a killed sweep with no
recomputation of completed points.

File format (all integers little-endian)::

    record := u32 payload_len | u32 crc32(payload) | payload
    file   := header-record, entry-record*

The header payload is a pickled ``{"magic", "schema"}`` dict; entry
payloads are pickled ``(key, value)`` pairs. Two write paths, two
guarantees:

* :meth:`CacheStore.save` rewrites the whole file via a temp file +
  ``os.replace`` — atomic on POSIX, so a crash mid-save leaves the old
  store intact, never a half-written one.
* :meth:`CacheStore.append` appends entry records to the existing file
  (creating it with a header first). A crash mid-append can only tear
  the *tail* record, and :meth:`load` recovers by keeping every record
  up to the first length/checksum violation.

:meth:`load` never raises on store damage: a missing file, foreign
magic, schema-version mismatch, or corrupt header all fall back to a
cold start (empty dict) with the reason recorded in
:attr:`CacheStore.last_load`. Schema bumps therefore cost a warm cache,
never a crashed server.
"""
from __future__ import annotations

import dataclasses
import io
import os
import pickle
import struct
import tempfile
import zlib

__all__ = ["CacheStore", "SCHEMA_VERSION", "MAGIC"]

#: Bump when the record families or fingerprint axes change shape in a
#: way pickle cannot bridge; old stores then load as a cold start.
#: v2: configs/options grew the §15 ``devices`` field — pre-v2 pickles
#: would unpickle into dataclasses missing it and break fingerprinting.
SCHEMA_VERSION = 2
MAGIC = "mcmcomm-sweep-cache"

_LEN = struct.Struct("<II")    # payload_len, crc32


@dataclasses.dataclass
class LoadInfo:
    """Outcome of the last :meth:`CacheStore.load` — cold-start reasons
    are data, not exceptions (the server logs them and proceeds)."""

    entries: int = 0
    cold_start: bool = False
    reason: str = ""
    torn_tail: bool = False     # file ended mid-record; prefix recovered


class CacheStore:
    def __init__(self, path: str):
        self.path = os.fspath(path)
        self.last_load = LoadInfo()

    # ------------------------------------------------------------ write
    def _header_bytes(self) -> bytes:
        return _pack_record(pickle.dumps(
            {"magic": MAGIC, "schema": SCHEMA_VERSION},
            protocol=pickle.HIGHEST_PROTOCOL))

    def save(self, entries: dict) -> int:
        """Atomically rewrite the store with ``entries``; returns the
        entry count. tmp-file + fsync + ``os.replace`` — a crash at any
        point leaves either the old file or the new one, never a mix."""
        buf = io.BytesIO()
        buf.write(self._header_bytes())
        for item in entries.items():
            buf.write(_pack_record(
                pickle.dumps(item, protocol=pickle.HIGHEST_PROTOCOL)))
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".sweep-cache-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(buf.getvalue())
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return len(entries)

    def append(self, entries: dict) -> int:
        """Append ``entries`` to the store (header written first if the
        file does not exist); returns the entry count. A crash mid-append
        tears at most the tail record — :meth:`load` drops it."""
        if not entries:
            return 0
        if not os.path.exists(self.path):
            return self.save(entries)
        with open(self.path, "ab") as f:
            for item in entries.items():
                f.write(_pack_record(
                    pickle.dumps(item, protocol=pickle.HIGHEST_PROTOCOL)))
            f.flush()
            os.fsync(f.fileno())
        return len(entries)

    # ------------------------------------------------------------- read
    def load(self) -> dict:
        """Read the store into ``{fingerprint: record}``. Damage never
        raises: bad header/magic/schema → cold start (``{}``); a torn
        tail record → the intact prefix. Duplicate keys (an appended
        re-solve) resolve last-writer-wins. Details in
        :attr:`last_load`."""
        info = LoadInfo()
        self.last_load = info
        if not os.path.exists(self.path):
            info.cold_start, info.reason = True, "no store file"
            return {}
        with open(self.path, "rb") as f:
            blob = f.read()
        records, torn = _unpack_records(blob)
        info.torn_tail = torn
        if not records:
            info.cold_start, info.reason = True, "empty/unreadable store"
            return {}
        try:
            header = pickle.loads(records[0])
            magic, schema = header["magic"], header["schema"]
        except Exception:
            info.cold_start, info.reason = True, "corrupt header"
            return {}
        if magic != MAGIC:
            info.cold_start, info.reason = True, f"foreign magic {magic!r}"
            return {}
        if schema != SCHEMA_VERSION:
            info.cold_start = True
            info.reason = (f"schema {schema} != {SCHEMA_VERSION} "
                           f"(cold start)")
            return {}
        out: dict = {}
        for payload in records[1:]:
            try:
                key, value = pickle.loads(payload)
            except Exception:
                # An unpicklable entry (e.g. written by a newer code
                # version) skips just that entry, not the store.
                info.torn_tail = True
                continue
            out[key] = value
        info.entries = len(out)
        return out


def _pack_record(payload: bytes) -> bytes:
    return _LEN.pack(len(payload), zlib.crc32(payload)) + payload


def _unpack_records(blob: bytes) -> tuple[list[bytes], bool]:
    """Split a store blob into payloads; stops at the first torn record
    (short length prefix, short payload, or checksum mismatch) and
    reports whether anything was dropped."""
    records: list[bytes] = []
    off, n = 0, len(blob)
    while off < n:
        if off + _LEN.size > n:
            return records, True
        length, crc = _LEN.unpack_from(blob, off)
        off += _LEN.size
        if off + length > n:
            return records, True
        payload = blob[off: off + length]
        if zlib.crc32(payload) != crc:
            return records, True
        records.append(payload)
        off += length
    return records, False
